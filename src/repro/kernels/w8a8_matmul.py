"""Pallas TPU kernel: int8 x int8 -> int32 matmul with PDQ requant epilogue.

The PDQ-critical property: the output requantization scale ``s_out`` is an
*input* to the kernel (predicted by the surrogate before the matmul runs),
so the int32 MXU accumulator is collapsed to int8 inside the epilogue and
the fp32/bf16 output tile never round-trips through HBM.  A dynamic-quant
epilogue cannot do this - it needs the full output materialized to find its
range first (the paper's O(b' * h) overhead, transposed to HBM traffic).

Two epilogues share the kernel (see DESIGN.md Sec. 2): ``requant`` emits
int8 for consumers that stay integer (KV-cache writes, stacked projections);
``fp_clamp`` emits bf16/f32 clamped to the PDQ-predicted per-row interval
[lo, hi], so chained fp consumers (residual adds, norms) skip the
requant -> dequant double rounding and the int8 intermediate entirely.

Grouped execution (DESIGN.md "Grouped execution"): with ``per_nblock=True``
the epilogue operands (s_out, z_out, lo, hi) are shaped (M, N/bn) and
indexed by the N-grid coordinate, so each 128-lane output block carries its
own surrogate interval.  Sibling projections concatenated along N (each
segment padded to the block boundary) then run as ONE wide matmul off ONE
prologue while every segment keeps its own PDQ grid.

Tiling: (bm, bn, bk) = (128, 128, 128) by default - MXU-aligned; the int32
accumulator lives in VMEM scratch across the K grid dimension.

Zero-point convention: activations are affine (z_x), weights symmetric
(z_w = 0, standard practice), so

    y = s_x * s_w * (x_q @ w_q - z_x * colsum(w_q)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, sx_ref, zx_ref, sw_ref, colsum_ref, sout_ref, zout_ref,
            lo_ref, hi_ref, o_ref, acc_ref, *, n_k: int, requant: bool,
            fp_clamp: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...] - zx_ref[...] * colsum_ref[...]          # (bm, bn)
        y = acc.astype(jnp.float32) * (sx_ref[...] * sw_ref[...])
        if requant:
            q = jnp.round(y / sout_ref[...]) + zout_ref[...].astype(jnp.float32)
            o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
        else:
            if fp_clamp:
                # PDQ fp-out epilogue: the surrogate-predicted interval is
                # applied in-register, so chained fp consumers skip the
                # int8 requant -> dequant double rounding entirely.
                y = jnp.clip(y, lo_ref[...], hi_ref[...])
            o_ref[...] = y.astype(o_ref.dtype)


def w8a8_matmul_p(
    x_q: jax.Array,       # (M, K) int8
    w_q: jax.Array,       # (K, N) int8
    s_x: jax.Array,       # (M, 1) f32
    z_x: jax.Array,       # (M, 1) i32
    s_w: jax.Array,       # (1, N) f32
    colsum: jax.Array,    # (1, N) i32  (precomputed at weight-deploy time)
    s_out: jax.Array,     # (M, 1) f32  (ignored unless requant)
    z_out: jax.Array,     # (M, 1) i32
    lo: jax.Array | None = None,   # (M, 1) f32  (fp_clamp only)
    hi: jax.Array | None = None,   # (M, 1) f32
    *,
    requant: bool,
    fp_clamp: bool = False,
    per_nblock: bool = False,
    block: tuple[int, int, int] = (128, 128, 128),
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Raw pallas call; all dims must already be multiples of the block.

    Epilogue modes: ``requant=True`` collapses the int32 accumulator to int8
    with (s_out, z_out); ``fp_clamp=True`` (requires requant=False) emits
    ``out_dtype`` clamped to the PDQ-predicted per-row interval [lo, hi].

    ``per_nblock=True`` makes the epilogue interval per-(row, N-block):
    s_out/z_out/lo/hi must then be shaped (M, N/bn) and are indexed by the
    N-grid coordinate, giving every 128-lane output segment its own
    surrogate grid (the grouped-projection path).
    """
    M, K = x_q.shape
    _, N = w_q.shape
    bm, bn, bk = block
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (
        f"w8a8_matmul_p requires block-multiple shapes: got x_q ({M}, {K}), "
        f"w_q ({K}, {N}) with block ({bm}, {bn}, {bk}); pad the inputs or "
        f"call repro.kernels.ops.w8a8_matmul, which pads for you")
    assert not (requant and fp_clamp), "requant and fp_clamp are exclusive"
    if lo is None:
        lo = jnp.zeros((M, 1 if not per_nblock else N // bn), jnp.float32)
    if hi is None:
        hi = jnp.zeros((M, 1 if not per_nblock else N // bn), jnp.float32)
    epi_cols = N // bn if per_nblock else 1
    for name, op in (("s_out", s_out), ("z_out", z_out), ("lo", lo), ("hi", hi)):
        assert op.shape == (M, epi_cols), (
            f"{name} must be (M, {epi_cols}) with per_nblock={per_nblock}, "
            f"got {op.shape}")
    epi_idx = (lambda i, j, k: (i, j)) if per_nblock else (lambda i, j, k: (i, 0))
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    kern = functools.partial(_kernel, n_k=n_k, requant=requant,
                             fp_clamp=fp_clamp)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s_x
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # z_x
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # s_w
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # colsum
            pl.BlockSpec((bm, 1), epi_idx),                   # s_out
            pl.BlockSpec((bm, 1), epi_idx),                   # z_out
            pl.BlockSpec((bm, 1), epi_idx),                   # lo
            pl.BlockSpec((bm, 1), epi_idx),                   # hi
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8 if requant else out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, s_x, z_x, s_w, colsum, s_out, z_out, lo, hi)


# ---------------------------------------------------------------------------
# SwiGLU + next-prologue epilogue (the serving MLP's fused fast path)
# ---------------------------------------------------------------------------


def _swiglu_kernel(x_ref, w_ref, sx_ref, zx_ref, sw_ref, colsum_ref,
                   lo_ref, hi_ref,
                   o_ref, hsw_ref, hswq_ref, osx_ref, os1_ref, os2_ref,
                   acc_ref, stage_ref, *, n_j: int, n_k: int, bn: int, P: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...] - zx_ref[...] * colsum_ref[...]          # (bm, bn)
        y = acc.astype(jnp.float32) * (sx_ref[...] * sw_ref[...])
        y = jnp.clip(y, lo_ref[...], hi_ref[...])
        o_ref[...] = y.astype(o_ref.dtype)
        # stage the clamped fp row block: the grid is row-major (j then k
        # fastest within one i), so by (j == n_j-1, k == n_k-1) the whole
        # (bm, N) output row lives in scratch and the SwiGLU pairing +
        # next-layer prologue can run without a second launch.
        pl.store(stage_ref, (slice(None), pl.ds(j * bn, bn)), y)

    @pl.when((k == n_k - 1) & (j == n_j - 1))
    def _swiglu_prologue():
        g = stage_ref[:, :P]                                        # gate
        u = stage_ref[:, P:]                                        # up
        hsw = jax.nn.silu(g) * u                                    # (bm, P)
        hsw_ref[...] = hsw
        # PDQ prologue of the w_down projection (ref.pdq_prologue_ref
        # semantics on the (bm, P) rows): lane-padding columns of both
        # segments are exactly 0 (zero weights, interval widened to
        # contain 0), so reducing over the padded extent equals reducing
        # over the real d_ff columns.
        amax = jnp.maximum(jnp.max(jnp.abs(hsw), axis=-1, keepdims=True), 1e-8)
        sx = amax / 127.0
        osx_ref[...] = sx
        os1_ref[...] = jnp.sum(hsw, axis=-1, keepdims=True)
        os2_ref[...] = jnp.sum(hsw * hsw, axis=-1, keepdims=True)
        hswq_ref[...] = jnp.clip(jnp.round(hsw / sx), -127.0, 127.0).astype(jnp.int8)


def w8a8_swiglu_matmul_p(
    x_q: jax.Array,       # (M, K) int8
    w_q: jax.Array,       # (K, N) int8: [gate | up], each P = N/2 columns
    s_x: jax.Array,       # (M, 1) f32
    z_x: jax.Array,       # (M, 1) i32
    s_w: jax.Array,       # (1, N) f32
    colsum: jax.Array,    # (1, N) i32
    lo: jax.Array,        # (M, N/bn) f32 per-(row, N-block) PDQ interval
    hi: jax.Array,        # (M, N/bn) f32
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> tuple[jax.Array, ...]:
    """Grouped gate/up W8A8 matmul whose epilogue ALSO computes the SwiGLU
    pairing silu(gate) * up and the next (w_down) projection's PDQ prologue.

    The epilogue stages each clamped (bm, bn) output block in a (bm, N)
    VMEM scratch; at the last (j, k) grid step of a row block the full row
    is resident, so the elementwise pairing and the one-pass prologue
    reduction run in-register - the quantized serving MLP then needs no
    standalone ``pdq_prologue_p`` launch between its two matmuls.

    Requires the two segments to occupy equal column extents P = N/2
    (gate columns [0, P), up columns [P, N) - the ``group_quantize_weights``
    layout for (w_gate, w_up)).  Returns
    (y (M, N) ``out_dtype``, hsw (M, P) f32, hsw_q (M, P) int8,
     s_x, s1, s2 each (M, 1) f32) with hsw = silu(y[:, :P]) * y[:, P:]
    and (hsw_q, s_x, s1, s2) = pdq_prologue(hsw).
    """
    M, K = x_q.shape
    _, N = w_q.shape
    bm, bn, bk = block
    assert N % 2 == 0, N
    P = N // 2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and P % bn == 0, (
        f"w8a8_swiglu_matmul_p requires block-multiple shapes: got x_q "
        f"({M}, {K}), w_q ({K}, {N}) with block ({bm}, {bn}, {bk}); pad the "
        f"inputs or call repro.kernels.ops.pdq_mlp, which pads for you")
    nb = N // bn
    assert lo.shape == (M, nb) and hi.shape == (M, nb), (lo.shape, hi.shape)
    n_k = K // bk
    grid = (M // bm, nb, n_k)
    epi_idx = lambda i, j, k: (i, j)                                # noqa: E731
    kern = functools.partial(_swiglu_kernel, n_j=nb, n_k=n_k, bn=bn, P=P)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s_x
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # z_x
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # s_w
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # colsum
            pl.BlockSpec((bm, 1), epi_idx),                   # lo
            pl.BlockSpec((bm, 1), epi_idx),                   # hi
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # y
            pl.BlockSpec((bm, P), lambda i, j, k: (i, 0)),    # hsw
            pl.BlockSpec((bm, P), lambda i, j, k: (i, 0)),    # hsw_q
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s_x out
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s1
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s2
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), out_dtype),
            jax.ShapeDtypeStruct((M, P), jnp.float32),
            jax.ShapeDtypeStruct((M, P), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, N), jnp.float32)],
        interpret=interpret,
    )(x_q, w_q, s_x, z_x, s_w, colsum, lo, hi)
