"""Pallas TPU kernel: int8 x int8 -> int32 matmul with PDQ requant epilogue.

The PDQ-critical property: the output requantization scale ``s_out`` is an
*input* to the kernel (predicted by the surrogate before the matmul runs),
so the int32 MXU accumulator is collapsed to int8 inside the epilogue and
the fp32/bf16 output tile never round-trips through HBM.  A dynamic-quant
epilogue cannot do this - it needs the full output materialized to find its
range first (the paper's O(b' * h) overhead, transposed to HBM traffic).

Two epilogues share the kernel (see DESIGN.md Sec. 2): ``requant`` emits
int8 for consumers that stay integer (KV-cache writes, stacked projections);
``fp_clamp`` emits bf16/f32 clamped to the PDQ-predicted per-row interval
[lo, hi], so chained fp consumers (residual adds, norms) skip the
requant -> dequant double rounding and the int8 intermediate entirely.

Grouped execution (DESIGN.md "Grouped execution"): with ``per_nblock=True``
the epilogue operands (s_out, z_out, lo, hi) are shaped (M, N/bn) and
indexed by the N-grid coordinate, so each 128-lane output block carries its
own surrogate interval.  Sibling projections concatenated along N (each
segment padded to the block boundary) then run as ONE wide matmul off ONE
prologue while every segment keeps its own PDQ grid.

Tiling: (bm, bn, bk) = (128, 128, 128) by default - MXU-aligned; the int32
accumulator lives in VMEM scratch across the K grid dimension.

Zero-point convention: activations are affine (z_x), weights symmetric
(z_w = 0, standard practice), so

    y = s_x * s_w * (x_q @ w_q - z_x * colsum(w_q)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, sx_ref, zx_ref, sw_ref, colsum_ref, sout_ref, zout_ref,
            lo_ref, hi_ref, o_ref, acc_ref, *, n_k: int, requant: bool,
            fp_clamp: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...] - zx_ref[...] * colsum_ref[...]          # (bm, bn)
        y = acc.astype(jnp.float32) * (sx_ref[...] * sw_ref[...])
        if requant:
            q = jnp.round(y / sout_ref[...]) + zout_ref[...].astype(jnp.float32)
            o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
        else:
            if fp_clamp:
                # PDQ fp-out epilogue: the surrogate-predicted interval is
                # applied in-register, so chained fp consumers skip the
                # int8 requant -> dequant double rounding entirely.
                y = jnp.clip(y, lo_ref[...], hi_ref[...])
            o_ref[...] = y.astype(o_ref.dtype)


def w8a8_matmul_p(
    x_q: jax.Array,       # (M, K) int8
    w_q: jax.Array,       # (K, N) int8
    s_x: jax.Array,       # (M, 1) f32
    z_x: jax.Array,       # (M, 1) i32
    s_w: jax.Array,       # (1, N) f32
    colsum: jax.Array,    # (1, N) i32  (precomputed at weight-deploy time)
    s_out: jax.Array,     # (M, 1) f32  (ignored unless requant)
    z_out: jax.Array,     # (M, 1) i32
    lo: jax.Array | None = None,   # (M, 1) f32  (fp_clamp only)
    hi: jax.Array | None = None,   # (M, 1) f32
    *,
    requant: bool,
    fp_clamp: bool = False,
    per_nblock: bool = False,
    block: tuple[int, int, int] = (128, 128, 128),
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Raw pallas call; all dims must already be multiples of the block.

    Epilogue modes: ``requant=True`` collapses the int32 accumulator to int8
    with (s_out, z_out); ``fp_clamp=True`` (requires requant=False) emits
    ``out_dtype`` clamped to the PDQ-predicted per-row interval [lo, hi].

    ``per_nblock=True`` makes the epilogue interval per-(row, N-block):
    s_out/z_out/lo/hi must then be shaped (M, N/bn) and are indexed by the
    N-grid coordinate, giving every 128-lane output segment its own
    surrogate grid (the grouped-projection path).
    """
    M, K = x_q.shape
    _, N = w_q.shape
    bm, bn, bk = block
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (
        f"w8a8_matmul_p requires block-multiple shapes: got x_q ({M}, {K}), "
        f"w_q ({K}, {N}) with block ({bm}, {bn}, {bk}); pad the inputs or "
        f"call repro.kernels.ops.w8a8_matmul, which pads for you")
    assert not (requant and fp_clamp), "requant and fp_clamp are exclusive"
    if lo is None:
        lo = jnp.zeros((M, 1 if not per_nblock else N // bn), jnp.float32)
    if hi is None:
        hi = jnp.zeros((M, 1 if not per_nblock else N // bn), jnp.float32)
    epi_cols = N // bn if per_nblock else 1
    for name, op in (("s_out", s_out), ("z_out", z_out), ("lo", lo), ("hi", hi)):
        assert op.shape == (M, epi_cols), (
            f"{name} must be (M, {epi_cols}) with per_nblock={per_nblock}, "
            f"got {op.shape}")
    epi_idx = (lambda i, j, k: (i, j)) if per_nblock else (lambda i, j, k: (i, 0))
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    kern = functools.partial(_kernel, n_k=n_k, requant=requant,
                             fp_clamp=fp_clamp)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # s_x
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # z_x
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # s_w
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # colsum
            pl.BlockSpec((bm, 1), epi_idx),                   # s_out
            pl.BlockSpec((bm, 1), epi_idx),                   # z_out
            pl.BlockSpec((bm, 1), epi_idx),                   # lo
            pl.BlockSpec((bm, 1), epi_idx),                   # hi
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8 if requant else out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, s_x, z_x, s_w, colsum, s_out, z_out, lo, hi)
