"""Pallas TPU kernel: fused PDQ prologue for the int8 serving path.

ONE read of the activation tile from HBM produces everything the W8A8
matmul needs *before* it runs:

  * ``x_q``  - per-row symmetric int8 quantization of x,
  * ``s_x``  - the per-row scale (amax / 127),
  * ``s1``   - per-row sum x   (paper Eq. 8 surrogate input),
  * ``s2``   - per-row sum x^2 (paper Eq. 9 surrogate input).

The unfused path reads x three times (amax pass, quantize pass, act_stats
pass); this kernel stages a (bm, K) row block in VMEM and performs a
two-stage amax reduction over k-chunks - stage 1 accumulates per-chunk
partial amax/s1/s2, stage 2 revisits the staged chunks to quantize with
the now-known row scale - so HBM traffic is exactly one read of x plus
one int8 write of x_q and O(M) scalars.

Grid: (M // bm,); the full K extent of a row block lives in VMEM (the
wrapper in ``ops.py`` shrinks bm for very large K to stay within VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, xq_ref, sx_ref, s1_ref, s2_ref, *, n_k: int, bk: int):
    # Stage 1: per-chunk partial reductions over the staged row block.
    xb = x_ref[:, 0:bk].astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    s1 = jnp.sum(xb, axis=-1, keepdims=True)
    s2 = jnp.sum(xb * xb, axis=-1, keepdims=True)
    for k in range(1, n_k):
        xb = x_ref[:, k * bk:(k + 1) * bk].astype(jnp.float32)
        amax = jnp.maximum(amax, jnp.max(jnp.abs(xb), axis=-1, keepdims=True))
        s1 = s1 + jnp.sum(xb, axis=-1, keepdims=True)
        s2 = s2 + jnp.sum(xb * xb, axis=-1, keepdims=True)

    amax = jnp.maximum(amax, 1e-8)
    scale = amax / 127.0
    sx_ref[...] = scale
    s1_ref[...] = s1
    s2_ref[...] = s2

    # Stage 2: quantize the (still-VMEM-resident) chunks with the row scale.
    r = 1.0 / scale
    for k in range(n_k):
        xb = x_ref[:, k * bk:(k + 1) * bk].astype(jnp.float32)
        xq_ref[:, k * bk:(k + 1) * bk] = jnp.clip(
            jnp.round(xb * r), -127.0, 127.0).astype(jnp.int8)


def pdq_prologue_p(
    x: jax.Array,                      # (M, K) float
    *,
    block: tuple[int, int] = (128, 512),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Raw pallas call; returns (x_q (M,K) i8, s_x, s1, s2 each (M,1) f32).

    M and K must already be multiples of the block (the ``ops.pdq_prologue``
    wrapper pads).
    """
    M, K = x.shape
    bm, bk = block
    assert M % bm == 0 and K % bk == 0, (
        f"pdq_prologue_p requires block-multiple shapes: got x ({M}, {K}) "
        f"with block ({bm}, {bk}); pad the inputs or call "
        f"repro.kernels.ops.pdq_prologue, which pads for you")
    grid = (M // bm,)
    kern = functools.partial(_kernel, n_k=K // bk, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
