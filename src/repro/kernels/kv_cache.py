"""Pallas TPU kernels for the serving KV-cache pool: flash-decode attention
over an int8-quantized cache, and the batched scatter-write the bucketed
prefill scheduler uses to land a whole prefill batch into the pooled cache
in one launch - over whole-sequence slot rows (``cache_scatter_p``) or
fixed-size pages of the paged pool (``cache_scatter_pages_p``).

Beyond-paper extension (DESIGN.md Sec. 2): the KV cache is stored int8 with
PDQ-predicted per-token-per-head scales, halving (vs bf16) the decode
memory-roofline term.  The kernel streams int8 K/V tiles HBM -> VMEM,
dequantizes in-register, and runs the online-softmax recurrence, so the
fp-dequantized cache never exists in HBM.

Layout: one query token, grouped-query attention (H = G * Hkv).
Grid (Hkv, S/bs); m/l/acc live in VMEM scratch across the S dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_s: int, bs: int, scale: float):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    offs = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = offs < length                                        # (1, bs)

    qb = q_ref[0]                                               # (G, Dh)
    kf = k_ref[0].astype(jnp.float32) * ks_ref[...].reshape(bs, 1)   # (bs, Dh)
    vf = v_ref[0].astype(jnp.float32) * vs_ref[...].reshape(bs, 1)

    logits = jnp.dot(qb, kf.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_ref[...]                                         # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new) * mask.astype(jnp.float32)      # (G, bs)
    corr = jnp.exp(m_prev - m_new)                              # (G, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, vf, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attend_i8kv_p(
    q: jax.Array,        # (Hkv, G, Dh) f32
    k_q: jax.Array,      # (Hkv, S, Dh) int8
    v_q: jax.Array,      # (Hkv, S, Dh) int8
    k_scale: jax.Array,  # (Hkv, S) f32
    v_scale: jax.Array,  # (Hkv, S) f32
    length: jax.Array,   # (1, 1) int32
    *,
    bs: int = 256,
    interpret: bool = False,
) -> jax.Array:
    Hkv, G, Dh = q.shape
    S = k_q.shape[1]
    bs = min(bs, S)
    assert S % bs == 0, (
        f"decode_attend_i8kv_p requires block-multiple shapes: S ({S}) must "
        f"be a multiple of bs ({bs}); pad the cache or call "
        f"repro.kernels.ops.decode_attend_i8kv, which pads for you")
    n_s = S // bs
    grid = (Hkv, n_s)
    kern = functools.partial(_kernel, n_s=n_s, bs=bs, scale=1.0 / (Dh ** 0.5))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, s: (0, 0)),          # length
            pl.BlockSpec((1, G, Dh), lambda h, s: (h, 0, 0)),   # q
            pl.BlockSpec((1, bs, Dh), lambda h, s: (h, s, 0)),  # k
            pl.BlockSpec((1, bs, Dh), lambda h, s: (h, s, 0)),  # v
            pl.BlockSpec((1, bs), lambda h, s: (h, s)),         # k_scale
            pl.BlockSpec((1, bs), lambda h, s: (h, s)),         # v_scale
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda h, s: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Hkv, G, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k_q, v_q, k_scale, v_scale)


def _fused_kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, oq_ref, sx_ref, s1_ref, s2_ref,
                  m_ref, l_ref, acc_ref, oall_ref, *,
                  n_hkv: int, n_s: int, bs: int, scale: float, G: int):
    h = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    offs = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = offs < length                                        # (1, bs)

    qb = q_ref[0]                                               # (G, Dh)
    kf = k_ref[0].astype(jnp.float32) * ks_ref[...].reshape(bs, 1)   # (bs, Dh)
    vf = v_ref[0].astype(jnp.float32) * vs_ref[...].reshape(bs, 1)

    logits = jnp.dot(qb, kf.T, preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_ref[...]                                         # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new) * mask.astype(jnp.float32)      # (G, bs)
    corr = jnp.exp(m_prev - m_new)                              # (G, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, vf, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finish():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)       # (G, Dh)
        o_ref[0] = o.astype(o_ref.dtype)
        # stage this head's normalized rows for the output-stage prologue
        oall_ref[pl.ds(h * G, G), :] = o

    @pl.when((s == n_s - 1) & (h == n_hkv - 1))
    def _prologue():
        # output stage: the wo projection's PDQ prologue over the FULL
        # flattened (H * Dh) attention output of this batch row, emitted
        # from the same launch - no separate pdq_prologue pass runs before
        # the wo matmul (see ops.decode_attend_i8kv / DESIGN.md "Decode
        # fast path").  Semantics match ref.pdq_prologue_ref on the
        # flattened row exactly.
        oa = oall_ref[...]                                      # (H, Dh) f32
        amax = jnp.maximum(jnp.max(jnp.abs(oa)), 1e-8)
        sx = amax / 127.0
        sx_ref[0, 0] = sx
        s1_ref[0, 0] = jnp.sum(oa)
        s2_ref[0, 0] = jnp.sum(oa * oa)
        oq_ref[...] = jnp.clip(jnp.round(oa / sx), -127.0, 127.0).astype(jnp.int8)


def decode_attend_i8kv_fused_p(
    q: jax.Array,        # (Hkv, G, Dh) f32
    k_q: jax.Array,      # (Hkv, S, Dh) int8
    v_q: jax.Array,      # (Hkv, S, Dh) int8
    k_scale: jax.Array,  # (Hkv, S) f32
    v_scale: jax.Array,  # (Hkv, S) f32
    length: jax.Array,   # (1, 1) int32
    *,
    bs: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """``decode_attend_i8kv_p`` plus the wo projection's fused PDQ prologue
    in the output stage.

    Returns (o (Hkv, G, Dh) f32, o_q (H, Dh) int8, s_x, s1, s2 each (1, 1)
    f32) where (o_q, s_x, s1, s2) are ``pdq_prologue_ref`` of the flattened
    (H * Dh,) output row: everything the downstream W8A8 wo matmul needs,
    with zero extra launches.  The fp ``o`` is still emitted (it is live in
    VMEM anyway) for the guarded-fallback path and fp consumers.
    """
    Hkv, G, Dh = q.shape
    H = Hkv * G
    S = k_q.shape[1]
    bs = min(bs, S)
    assert S % bs == 0, (
        f"decode_attend_i8kv_fused_p requires block-multiple shapes: S ({S}) "
        f"must be a multiple of bs ({bs}); pad the cache or call "
        f"repro.kernels.ops.decode_attend_i8kv, which pads for you")
    n_s = S // bs
    grid = (Hkv, n_s)
    kern = functools.partial(_fused_kernel, n_hkv=Hkv, n_s=n_s, bs=bs,
                             scale=1.0 / (Dh ** 0.5), G=G)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, s: (0, 0)),          # length
            pl.BlockSpec((1, G, Dh), lambda h, s: (h, 0, 0)),   # q
            pl.BlockSpec((1, bs, Dh), lambda h, s: (h, s, 0)),  # k
            pl.BlockSpec((1, bs, Dh), lambda h, s: (h, s, 0)),  # v
            pl.BlockSpec((1, bs), lambda h, s: (h, s)),         # k_scale
            pl.BlockSpec((1, bs), lambda h, s: (h, s)),         # v_scale
        ],
        out_specs=[
            pl.BlockSpec((1, G, Dh), lambda h, s: (h, 0, 0)),   # o
            pl.BlockSpec((H, Dh), lambda h, s: (0, 0)),         # o_q
            pl.BlockSpec((1, 1), lambda h, s: (0, 0)),          # s_x
            pl.BlockSpec((1, 1), lambda h, s: (0, 0)),          # s1
            pl.BlockSpec((1, 1), lambda h, s: (0, 0)),          # s2
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Hkv, G, Dh), jnp.float32),
            jax.ShapeDtypeStruct((H, Dh), jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((H, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k_q, v_q, k_scale, v_scale)


# ---------------------------------------------------------------------------
# Pooled-cache slot scatter (bucketed batched prefill)
# ---------------------------------------------------------------------------


def _scatter_kernel(map_ref, dst_ref, src_ref, out_ref):
    b = pl.program_id(0)
    take = map_ref[b] >= 0

    @pl.when(take)
    def _take():
        out_ref[...] = src_ref[...]

    @pl.when(jnp.logical_not(take))
    def _keep():
        out_ref[...] = dst_ref[...]


def cache_scatter_p(
    src_map: jax.Array,  # (B,) int32: source row per dst row, or -1 = keep
    dst: jax.Array,      # (B, R) any dtype (int8 kernel-layout KV included)
    src: jax.Array,      # (Bs, R) same dtype
    *,
    br: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    """out[b] = src[src_map[b]] if src_map[b] >= 0 else dst[b] (bit-exact).

    One launch scatters a whole prefill batch of cache rows into the pooled
    serving cache.  ``src_map`` is scalar-prefetched so the src BlockSpec
    index map can chase it (clamped to row 0 for passthrough rows - the
    block is still streamed, but the kernel writes the dst copy instead).
    Grid (B, R/br); rows are blocked along R so arbitrarily large KV leaves
    never exceed VMEM.
    """
    B, R = dst.shape
    assert src.ndim == 2 and src.shape[1] == R and src.dtype == dst.dtype
    assert R % 128 == 0, (
        f"cache_scatter_p requires the flattened row extent R ({R}) to be a "
        f"128-lane multiple; pad the row (ops.cache_scatter_rows does)")
    # largest 128-multiple divisor of R that is <= br (R % 128 == 0, so the
    # scan always terminates at br == 128)
    br = max(min(br, R) - min(br, R) % 128, 128)
    while R % br:
        br -= 128
    grid = (B, R // br)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br), lambda b, r, m: (b, r)),
            pl.BlockSpec((1, br), lambda b, r, m: (jnp.maximum(m[b], 0), r)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda b, r, m: (b, r)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R), dst.dtype),
        interpret=interpret,
    )(src_map.astype(jnp.int32), dst, src)


def cache_scatter_pages_p(
    page_map: jax.Array,  # (N,) int32: source page-row per pool page, or -1
    dst: jax.Array,       # (N, R) physical page pool, R = one page's elements
    src: jax.Array,       # (M, R) page-rows (a logical cache leaf reshaped)
    *,
    br: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    """Paged generalization of ``cache_scatter_p``: rows are fixed-size
    cache PAGES instead of whole-sequence slot rows.

    The scalar-prefetched machinery is identical - the map is prefetched,
    the src BlockSpec chases ``max(page_map[n], 0)``, and -1 entries keep
    the dst page bit-exactly - but the row extent R is one page's elements
    (page_size x heads x head_dim), so a single launch moves an arbitrary
    subset of pool pages with no host round-trip.  Both directions of the
    paged pool ride this one kernel: LANDING a prefill (dst = pool pages,
    src = the prefill batch reshaped to page-rows, map = the allocator's
    page tables) and GATHERING for decode (dst = a zeroed per-slot scratch
    in page-rows, src = pool pages, map = the flattened page tables; -1
    table entries leave the scratch zero, matching the never-written
    region of a slot-row cache bit-exactly).
    """
    return cache_scatter_p(page_map, dst, src, br=br, interpret=interpret)
