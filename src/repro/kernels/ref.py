"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: kernel tests sweep shapes/dtypes and
assert_allclose against these references (interpret=True on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def w8a8_matmul_ref(
    x_q: jax.Array,        # (M, K) int8
    w_q: jax.Array,        # (K, N) int8
    s_x: jax.Array,        # () or (M, 1) float32 activation scale
    z_x: jax.Array,        # () or (M, 1) int32 activation zero-point
    s_w: jax.Array,        # () or (1, N) float32 weight scale (symmetric, z_w = 0)
    s_out: jax.Array | None = None,   # () or (M, 1): requantized int8 output
    z_out: jax.Array | None = None,
) -> jax.Array:
    """int8 x int8 -> int32 matmul with dequant (or requant) epilogue.

    y_fp = s_x * s_w * ( x_q @ w_q  -  z_x * colsum(w_q) )
    if (s_out, z_out) given: y_q = clamp(round(y_fp / s_out) + z_out, -128, 127)
    """
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)   # (1, N)
    acc = acc - z_x.astype(jnp.int32) * colsum
    y = acc.astype(jnp.float32) * (s_x.astype(jnp.float32) * s_w.astype(jnp.float32))
    if s_out is None:
        return y
    q = jnp.round(y / s_out) + z_out
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def pdq_prologue_ref(
    x: jax.Array,                      # (M, K) float
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused PDQ prologue oracle: one conceptual pass over x emits the
    symmetric int8 quantization, its per-row scale, and the surrogate sums.

    Returns (x_q (M,K) int8, s_x (M,1) f32, s1 (M,1) f32, s2 (M,1) f32)
    with s_x = max(|x|, eps)/127, s1 = sum_k x, s2 = sum_k x^2.
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1, keepdims=True), 1e-8)
    s_x = amax / 127.0
    x_q = jnp.clip(jnp.round(x32 / s_x), -127, 127).astype(jnp.int8)
    s1 = jnp.sum(x32, axis=-1, keepdims=True)
    s2 = jnp.sum(jnp.square(x32), axis=-1, keepdims=True)
    return x_q, s_x, s1, s2


def act_stats_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused row moments: s1 = sum_k x, s2 = sum_k x^2 for x (M, K) -> (M,), (M,)."""
    x = x.astype(jnp.float32)
    return jnp.sum(x, axis=-1), jnp.sum(jnp.square(x), axis=-1)


def quantize_ref(x: jax.Array, scale: jax.Array, zero_point: jax.Array) -> jax.Array:
    """Affine int8 quantize: clamp(round(x/scale) + z, -128, 127)."""
    q = jnp.round(x.astype(jnp.float32) / scale) + zero_point
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def dequantize_ref(q: jax.Array, scale: jax.Array, zero_point: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.int32) - zero_point).astype(dtype) * scale.astype(dtype)


def decode_attend_i8kv_ref(
    q: jax.Array,          # (H, Dh) float32 - one query token, H heads
    k_q: jax.Array,        # (S, Hkv, Dh) int8 quantized keys
    v_q: jax.Array,        # (S, Hkv, Dh) int8 quantized values
    k_scale: jax.Array,    # (S, Hkv) float32
    v_scale: jax.Array,    # (S, Hkv)
    length: jax.Array,     # () int32 - valid prefix of the cache
) -> jax.Array:
    """Flash-decode oracle with an int8 (symmetric, per-token-per-head) KV cache."""
    S, Hkv, Dh = k_q.shape
    H = q.shape[0]
    groups = H // Hkv
    k = k_q.astype(jnp.float32) * k_scale[..., None]
    v = v_q.astype(jnp.float32) * v_scale[..., None]
    k = jnp.repeat(k, groups, axis=1)          # (S, H, Dh)
    v = jnp.repeat(v, groups, axis=1)
    logits = jnp.einsum("hd,shd->hs", q, k) / jnp.sqrt(Dh).astype(jnp.float32)
    mask = jnp.arange(S) < length
    logits = jnp.where(mask[None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hs,shd->hd", p, v)
