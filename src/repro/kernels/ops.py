"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy (``set_impl``):
  'auto'   - real Pallas kernel on TPU, jnp reference on other backends
             (interpret-mode Pallas is a correctness tool, not a fast path).
  'kernel' - force the Pallas kernel (interpret=True off-TPU). Used by tests.
  'ref'    - force the pure-jnp oracle.

All wrappers accept arbitrary leading batch dims and handle padding to the
kernel's block multiples.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .act_stats import act_stats_p
from .kv_cache import (cache_scatter_p, cache_scatter_pages_p,
                       decode_attend_i8kv_fused_p, decode_attend_i8kv_p)
from .pdq_prologue import pdq_prologue_p
from .quantize import dequantize_p, quantize_p
from .w8a8_matmul import w8a8_matmul_p, w8a8_swiglu_matmul_p

_IMPL = "auto"


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "kernel", "ref")
    _IMPL = impl


def _use_kernel() -> bool:
    if _IMPL == "ref":
        return False
    if _IMPL == "kernel":
        return True
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Tensor parallelism (serving): column-split + all-gather epilogue
# ---------------------------------------------------------------------------
#
# Inside a shard_map body the sharded serving engine activates ``tp_shard``:
# every PDQ / fp projection then computes only its device's N-columns and
# all-gathers the result, so the matmul FLOPs (and on TPU the weight
# streaming) split over the mesh axis while the numerics stay bit-exact -
# each output column runs the identical full-K reduction and the identical
# per-row epilogue it runs on one device, and the tiled all-gather merely
# concatenates the column blocks in axis order.  The PDQ prologue is
# intentionally NOT split: its (x_q, s_x, s1, s2) depend on the whole input
# row, are O(K) to compute, and every shard needs them - recomputing
# locally is cheaper than a broadcast.

_TP: tuple[str, int] | None = None     # (mesh axis name, axis size)


@contextlib.contextmanager
def tp_shard(axis_name: str, size: int):
    """Enable N-column tensor parallelism over ``axis_name`` while tracing
    (valid only inside a shard_map body that binds the axis).  size == 1 is
    a no-op."""
    global _TP
    prev = _TP
    _TP = (axis_name, int(size)) if int(size) > 1 else None
    try:
        yield
    finally:
        _TP = prev


def tp_ctx() -> tuple[str, int] | None:
    return _TP


def _tp_cols(a, n_local: int, idx, axis: int):
    return jax.lax.dynamic_slice_in_dim(a, idx * n_local, n_local, axis)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Guarded PDQ fallback (fault tolerance)
# ---------------------------------------------------------------------------
#
# A corrupted int8 epilogue (bad surrogate interval, overflowed requant
# grid, a flipped bit in the weight record) shows up as NaN/Inf in the
# projection output.  With ``pdq_guard`` active while tracing, every PDQ
# fp-out projection checks its result device-side and - per projection,
# per launch - falls back to the plain fp-dequant matmul
# ``x @ (q * scale)`` when any element is non-finite.  The fallback branch
# is pure jnp (no pallas_call), so guarded programs keep the exact kernel
# census of unguarded ones; the finite check is one fused reduction per
# projection.  Engines opt in with ``pdq_fallback=True``.

_PDQ_GUARD = False
_PDQ_FAULT = False      # test hook: corrupt every fast-path result
_PDQ_TEL: "PdqTelemetryCollector | None" = None


@contextlib.contextmanager
def pdq_guard(enable: bool = True):
    """Enable the per-projection PDQ->fp-dequant fallback while tracing."""
    global _PDQ_GUARD
    prev = _PDQ_GUARD
    _PDQ_GUARD = bool(enable)
    try:
        yield
    finally:
        _PDQ_GUARD = prev


class PdqTelemetryCollector:
    """Trace-time accumulator for quantization-health scalars.

    While ``pdq_telemetry`` is active, every PDQ projection appends jnp
    SCALARS here as it traces: the guard's fallback-activation flag (the
    same fused finiteness reduction the guard's ``cond`` already
    computes), int8 clip-saturation hit counts and the elements checked.
    ``summary()`` folds them into ONE (3,) float32 the launch returns
    alongside its tokens - the host reads it in the existing token
    gather, so quantization health costs zero extra round-trips and adds
    no pallas_calls (pure jnp reductions; the kernel census is pinned
    unchanged)."""

    def __init__(self):
        self.fallbacks: list = []
        self.clip_hits: list = []
        self.clip_total: list = []

    def summary(self):
        def tot(xs):
            acc = jnp.float32(0.0)
            for x in xs:
                acc = acc + x
            return acc

        return jnp.stack([tot(self.fallbacks), tot(self.clip_hits),
                          tot(self.clip_total)])


# the summary layout engines unpack: [fallbacks, clip_hits, clip_total]
PDQ_TEL_WIDTH = 3


@contextlib.contextmanager
def pdq_telemetry(enable: bool = True):
    """Collect PDQ health scalars from every projection traced inside
    (nests with ``pdq_guard``/``tp_shard``).  ``enable=False`` yields a
    collector whose summary is zeros - launch signatures stay uniform."""
    global _PDQ_TEL
    col = PdqTelemetryCollector()
    prev = _PDQ_TEL
    _PDQ_TEL = col if enable else None
    try:
        yield col
    finally:
        _PDQ_TEL = prev


def _tel_clip(y, lo, hi):
    """Record clip saturation of a clamped fp output: elements sitting on
    either interval edge were clipped by the epilogue (or landed exactly
    on the representable boundary, which the rate treats the same)."""
    if _PDQ_TEL is None:
        return
    hits = jnp.sum(((y <= lo) | (y >= hi)).astype(jnp.float32))
    _PDQ_TEL.clip_hits.append(hits)
    _PDQ_TEL.clip_total.append(jnp.float32(y.size))


def _tel_clip_q(y_q):
    """Int8-out flavor: saturation is the grid's edge codes."""
    if _PDQ_TEL is None:
        return
    hits = jnp.sum(((y_q == 127) | (y_q == -128)).astype(jnp.float32))
    _PDQ_TEL.clip_hits.append(hits)
    _PDQ_TEL.clip_total.append(jnp.float32(y_q.size))


@contextlib.contextmanager
def pdq_fault():
    """Test-only: poison every guarded fast-path output with NaN while
    tracing, so the fallback branch is forced to carry the computation."""
    global _PDQ_FAULT
    prev = _PDQ_FAULT
    _PDQ_FAULT = True
    try:
        yield
    finally:
        _PDQ_FAULT = prev


def _fp_dequant_matmul(x, w_q, scale, out_dtype):
    """The always-available fallback precision: dequantize the int8 weight
    and run the projection in fp32.  No PDQ prologue, no requant grid - the
    only state it shares with the fast path is the weight record itself."""
    w = w_q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32).reshape(1, -1)
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def _guard_pdq(y, x, w_q, scale, out_dtype):
    """y if finite else the fp-dequant fallback (no-op unless pdq_guard)."""
    if not _PDQ_GUARD:
        return y
    if _PDQ_FAULT:
        y = y + jnp.float32(jnp.nan).astype(y.dtype)
    ok = jnp.isfinite(y).all()
    if _PDQ_TEL is not None:
        # the fallback-activation count rides the SAME fused reduction the
        # cond consumes: telemetry reuses it, costing nothing extra
        _PDQ_TEL.fallbacks.append(1.0 - ok.astype(jnp.float32))
    return jax.lax.cond(ok,
                        lambda: y,
                        lambda: _fp_dequant_matmul(x, w_q, scale, out_dtype))


def _pad_to(a: jax.Array, axis: int, mult: int, value=0):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _norm_row(a, M, dtype):
    """Broadcast a scalar / (M,) / (M,1) quantity to (M, 1)."""
    a = jnp.asarray(a, dtype)
    if a.ndim == 0:
        a = jnp.full((M, 1), a)
    return a.reshape(M, 1)


# ---------------------------------------------------------------------------


def w8a8_matmul(x_q, w_q, s_x, z_x, s_w, s_out=None, z_out=None, *,
                colsum=None, fp_range=None, out_dtype=jnp.float32,
                block=(128, 128, 128)):
    """y = s_x*s_w*(x_q @ w_q - z_x*colsum); requantized int8 iff s_out given.

    x_q: (..., K) int8; w_q: (K, N) int8. s_x/z_x: scalar, (...) or
    (..., 1) per-row; s_w: scalar or (N,) per-channel.

    ``fp_range=(lo, hi)`` (exclusive with s_out) applies the PDQ interval
    clamp inside the epilogue and emits ``out_dtype`` directly.

    Epilogue operands (s_out/z_out/lo/hi) accept two layouts: per-row
    (scalar, (...) or (..., 1)) or per-(row, N-block) - shaped
    (..., N // bn) with bn the N block - which gives each 128-lane output
    segment of a grouped matmul its own surrogate grid (requires N to be a
    multiple of bn; see ``pdq_dense_grouped``).
    """
    lead = x_q.shape[:-1]
    K = x_q.shape[-1]
    N = w_q.shape[-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x_q.reshape(M, K)
    s_w2 = jnp.asarray(s_w, jnp.float32)
    s_w2 = jnp.broadcast_to(s_w2.reshape(1, -1) if s_w2.ndim else s_w2, (1, N)).reshape(1, N)
    requant = s_out is not None
    fp_clamp = fp_range is not None
    assert not (requant and fp_clamp), "fp_range and s_out are exclusive"
    bm, bn, bk = block

    def _is_per_block(a):
        a = jnp.asarray(a)
        return a.ndim == len(lead) + 1 and a.shape[-1] > 1

    epi_in = (s_out if requant else 1.0, z_out if requant else 0,
              fp_range[0] if fp_clamp else 0.0, fp_range[1] if fp_clamp else 0.0)
    per_nblock = any(_is_per_block(a) for a in epi_in)
    if per_nblock:
        assert N % bn == 0, (
            f"per-(row, N-block) epilogue operands require N ({N}) to be a "
            f"multiple of the N block ({bn})")
        nb = N // bn

        def _norm_epi(a, dtype):
            a = jnp.asarray(a, dtype)
            if a.ndim == 0:
                return jnp.full((M, nb), a)
            a = a.reshape(M, -1)
            assert a.shape[1] in (1, nb), (
                f"epilogue operand has {a.shape[1]} columns; expected 1 "
                f"(per-row) or {nb} (per-N-block)")
            return jnp.broadcast_to(a, (M, nb))
    else:
        def _norm_epi(a, dtype):
            return _norm_row(a, M, dtype)

    sx = _norm_row(s_x, M, jnp.float32)
    zx = _norm_row(z_x, M, jnp.int32)
    so = _norm_epi(epi_in[0], jnp.float32)
    zo = _norm_epi(epi_in[1], jnp.int32)
    lo = _norm_epi(epi_in[2], jnp.float32)
    hi = _norm_epi(epi_in[3], jnp.float32)

    if not _use_kernel():
        if per_nblock:
            # expand per-block columns to per-channel (each block spans bn
            # lanes) so the jnp oracle broadcasts them exactly.
            so, zo, lo, hi = (jnp.repeat(a, bn, axis=-1) for a in (so, zo, lo, hi))
        y = ref.w8a8_matmul_ref(x2, w_q, sx, zx, s_w2,
                                so if requant else None, zo if requant else None)
        if fp_clamp:
            y = jnp.clip(y, lo, hi)
        if not requant:
            y = y.astype(out_dtype)
        return y.reshape(*lead, N)

    if colsum is None:
        colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    colsum = colsum.reshape(1, N)
    xp = _pad_to(_pad_to(x2, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_q, 0, bk), 1, bn)
    pads = dict(axis=0, mult=bm)
    y = w8a8_matmul_p(
        xp, wp,
        _pad_to(sx, **pads, value=1.0), _pad_to(zx, **pads),
        _pad_to(s_w2, 1, bn, value=1.0), _pad_to(colsum, 1, bn),
        _pad_to(so, **pads, value=1.0), _pad_to(zo, **pads),
        _pad_to(lo, **pads), _pad_to(hi, **pads),
        requant=requant, fp_clamp=fp_clamp, per_nblock=per_nblock,
        out_dtype=out_dtype, block=block, interpret=_interpret(),
    )
    return y[:M, :N].reshape(*lead, N)


def pdq_prologue(x, *, block=(128, 512)):
    """Fused serving-path prologue: ONE pass over x (..., K) emits
    (x_q int8 like x, s_x, s1, s2 each shaped (..., 1)).

    Replaces the separate amax / quantize / act_stats passes of the unfused
    path; see kernels/pdq_prologue.py for the dataflow.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    if not _use_kernel():
        x_q, s_x, s1, s2 = ref.pdq_prologue_ref(x2)
    else:
        bm, bk = block
        bk = min(bk, max(K, 1))
        Kp = K + (-K) % bk
        # the kernel stages a full (bm, Kp) row block in VMEM: shrink bm
        # for very long rows so the f32 staging stays well under VMEM.
        while bm > 8 and bm * Kp * 4 > 8 * 1024 * 1024:
            bm //= 2
        xp = _pad_to(_pad_to(x2, 1, bk), 0, bm)
        x_q, s_x, s1, s2 = pdq_prologue_p(xp, block=(bm, bk),
                                          interpret=_interpret())
        x_q = x_q[:M, :K]
        s_x, s1, s2 = s_x[:M], s1[:M], s2[:M]
    return (x_q.reshape(*lead, K), s_x.reshape(*lead, 1),
            s1.reshape(*lead, 1), s2.reshape(*lead, 1))


def pdq_interval(wrec, s1, s2):
    """PDQ surrogate interval from the prologue sums (paper Eqs. 8-9 + I(a,b)).

    s1/s2: (..., 1).  Returns (lo, hi, s_out, z_out) per row, where [lo, hi]
    is widened to contain 0 and (s_out, z_out) is the affine int8 grid over
    it.  O(M) scalar math - negligible next to the matmul.

    Grouped records carry (n_seg,) weight stats; the same expression then
    broadcasts (..., 1) x (n_seg,) -> (..., n_seg), pricing every segment's
    interval from the ONE shared (s1, s2) pair - the sharing is exact, not
    approximate, because the moments depend only on the input row.
    """
    mean = wrec["mu_w"] * s1
    sigma = jnp.sqrt(jnp.maximum(wrec["var_w"] * s2, 0.0)) + 1e-8
    lo = jnp.minimum(mean - wrec["alpha"] * sigma, 0.0)
    hi = jnp.maximum(mean + wrec["beta"] * sigma, 0.0)
    s_out = jnp.maximum((hi - lo) / 255.0, 1e-8)
    z_out = -jnp.round(lo / s_out) - 128.0
    return lo, hi, s_out, z_out


def pdq_dense(x, wrec, *, out="fp", out_dtype=None, block=(128, 128, 128),
              prologue_block=(128, 512)):
    """The fused PDQ serving-path dense layer: one prologue + one matmul.

    ``wrec`` is a weight record from ``models.linops.quantize_weight``:
    {'q' (K, N) int8, 'scale' (N,) f32, 'colsum' (1, N) i32,
     'mu_w', 'var_w', 'alpha', 'beta' scalars}.

    out='fp'  : returns y (..., N) in ``out_dtype`` (default f32); the PDQ
                interval is applied as a clamp inside the matmul epilogue,
                matching the requant->dequant path to one int8 step without
                materializing the int8 intermediate.
    out='int8': returns (y_q (..., N) int8, s_out (..., 1) f32,
                z_out (..., 1) i32) for consumers that stay integer.
    """
    assert out in ("fp", "int8"), out
    if out_dtype is None:
        out_dtype = jnp.float32
    x_q, s_x, s1, s2 = pdq_prologue(x, block=prologue_block)
    lo, hi, s_out, z_out = pdq_interval(wrec, s1, s2)
    if out == "int8":
        y_q = w8a8_matmul(x_q, wrec["q"], s_x, 0, wrec["scale"],
                          s_out, z_out.astype(jnp.int32),
                          colsum=wrec["colsum"], block=block)
        _tel_clip_q(y_q)
        return y_q, s_out, z_out.astype(jnp.int32)
    return pdq_dense_from_prologue(x, x_q, s_x, s1, s2, wrec,
                                   out_dtype=out_dtype, block=block)


def pdq_dense_from_prologue(x, x_q, s_x, s1, s2, wrec, *, out_dtype=None,
                            block=(128, 128, 128)):
    """``pdq_dense(out='fp')`` with the prologue already computed upstream.

    The serving decode path fuses the wo projection's prologue into the
    flash-decode attend kernel's output stage (``decode_attend_i8kv`` with
    ``wo_prologue=True``); this entry consumes those (x_q, s_x, s1, s2)
    directly, so the projection costs ONE pallas_call instead of two.  The
    fp ``x`` is still required: the guarded fallback and the TP fallback
    precision recompute from it.  Numerics are identical to ``pdq_dense``
    by construction (it is the same tail).
    """
    if out_dtype is None:
        out_dtype = jnp.float32
    lo, hi, s_out, z_out = pdq_interval(wrec, s1, s2)
    # clamp to the representable extent of the int8 grid rather than the raw
    # interval, so fp-out matches requant->dequant at the clip boundaries.
    lo_g = (-128.0 - z_out) * s_out
    hi_g = (127.0 - z_out) * s_out
    N = wrec["q"].shape[1]
    if _TP is not None and N % _TP[1] == 0:
        # column-TP: this shard's N-slice only (the interval is per-row, so
        # the epilogue operands need no slicing), then all-gather columns.
        ax, T = _TP
        idx = jax.lax.axis_index(ax)
        Nl = N // T
        wq_l = _tp_cols(wrec["q"], Nl, idx, 1)
        sc_l = _tp_cols(wrec["scale"], Nl, idx, 0)
        y = w8a8_matmul(x_q, wq_l, s_x, 0, sc_l,
                        colsum=_tp_cols(wrec["colsum"], Nl, idx, 1),
                        fp_range=(lo_g, hi_g), out_dtype=out_dtype, block=block)
        # telemetry counts this shard's columns; the engine psums the
        # collector summary over the mesh to recover fleet-wide counts
        _tel_clip(y, lo_g, hi_g)
        # guard BEFORE the all-gather: each shard checks and (if needed)
        # recomputes only its own columns, so one corrupted shard cannot
        # spread non-finite values through the gathered concatenation.
        y = _guard_pdq(y, x, wq_l, sc_l, out_dtype)
        return jax.lax.all_gather(y, ax, axis=y.ndim - 1, tiled=True)
    y = w8a8_matmul(x_q, wrec["q"], s_x, 0, wrec["scale"],
                    colsum=wrec["colsum"], fp_range=(lo_g, hi_g),
                    out_dtype=out_dtype, block=block)
    _tel_clip(y, lo_g, hi_g)
    return _guard_pdq(y, x, wrec["q"], wrec["scale"], out_dtype)


def pdq_dense_grouped(x, grec, *, out="fp", out_dtype=None,
                      block=(128, 128, 128), prologue_block=(128, 512)):
    """Grouped PDQ dense: ONE prologue + ONE wide W8A8 matmul for every
    projection consuming the same input (DESIGN.md "Grouped execution").

    ``grec`` is a record from ``models.linops.group_quantize_weights``:
    sibling weights concatenated along N (each segment padded to the
    128-lane boundary) with per-segment (n_seg,) surrogate stats and a
    static ``segs`` layout.  The prologue's (x_q, s_x, s1, s2) serve every
    segment; ``pdq_interval`` broadcasts to per-(row, segment) grids, which
    the matmul applies per N-block in its epilogue.

    out='fp'  : returns a tuple of per-segment outputs (..., N_i) in
                ``out_dtype`` (default f32).
    out='int8': returns (tuple of per-segment int8 outputs,
                s_out (..., n_seg) f32, z_out (..., n_seg) i32).
    """
    assert out in ("fp", "int8"), out
    if out_dtype is None:
        out_dtype = jnp.float32
    segs = grec["segs"]
    bm, bn, bk = block
    assert all(p % bn == 0 for p in segs.padded), (
        f"grouped segments are padded to 128 lanes; the N block ({bn}) must "
        f"divide every padded extent {segs.padded}")
    reps = np.array([p // bn for p in segs.padded])
    nb = int(reps.sum())
    x_q, s_x, s1, s2 = pdq_prologue(x, block=prologue_block)
    lo, hi, s_out, z_out = pdq_interval(grec, s1, s2)      # (..., n_seg)

    def blockwise(a):
        # per-segment -> per-N-block: segment i spans padded[i]/bn blocks
        return jnp.repeat(a, reps, axis=-1, total_repeat_length=nb)

    bounds = zip(segs.offsets, segs.sizes)
    if out == "int8":
        y_q = w8a8_matmul(x_q, grec["q"], s_x, 0, grec["scale"],
                          blockwise(s_out), blockwise(z_out).astype(jnp.int32),
                          colsum=grec["colsum"], block=block)
        _tel_clip_q(y_q)
        ys = tuple(y_q[..., o:o + n] for o, n in bounds)
        return ys, s_out, z_out.astype(jnp.int32)
    lo_g = (-128.0 - z_out) * s_out
    hi_g = (127.0 - z_out) * s_out
    if _TP is not None and nb % _TP[1] == 0:
        # the N-segments (and their per-(row, N-block) epilogue grids) split
        # along the TP axis in whole 128-lane blocks; the tiled all-gather
        # reassembles the full concatenation before the segment split.
        ax, T = _TP
        idx = jax.lax.axis_index(ax)
        nb_l, Nl = nb // T, segs.total // T
        lo_b, hi_b = blockwise(lo_g), blockwise(hi_g)
        wq_l = _tp_cols(grec["q"], Nl, idx, 1)
        sc_l = _tp_cols(grec["scale"], Nl, idx, 0)
        lo_l = _tp_cols(lo_b, nb_l, idx, lo_b.ndim - 1)
        hi_l = _tp_cols(hi_b, nb_l, idx, hi_b.ndim - 1)
        y = w8a8_matmul(x_q, wq_l, s_x, 0, sc_l,
                        colsum=_tp_cols(grec["colsum"], Nl, idx, 1),
                        fp_range=(lo_l, hi_l),
                        out_dtype=out_dtype, block=block)
        if _PDQ_TEL is not None:
            _tel_clip(y, jnp.repeat(lo_l, bn, axis=-1),
                      jnp.repeat(hi_l, bn, axis=-1))
        y = _guard_pdq(y, x, wq_l, sc_l, out_dtype)
        y = jax.lax.all_gather(y, ax, axis=y.ndim - 1, tiled=True)
        return tuple(y[..., o:o + n] for o, n in bounds)
    y = w8a8_matmul(x_q, grec["q"], s_x, 0, grec["scale"],
                    colsum=grec["colsum"],
                    fp_range=(blockwise(lo_g), blockwise(hi_g)),
                    out_dtype=out_dtype, block=block)
    if _PDQ_TEL is not None:
        _tel_clip(y, jnp.repeat(blockwise(lo_g), bn, axis=-1),
                  jnp.repeat(blockwise(hi_g), bn, axis=-1))
    y = _guard_pdq(y, x, grec["q"], grec["scale"], out_dtype)
    return tuple(y[..., o:o + n] for o, n in bounds)


def pdq_mlp(x, grec, down_rec, *, out_dtype=None, block=(128, 128, 128),
            prologue_block=(128, 512)):
    """Fused quantized SwiGLU MLP: gate/up grouped matmul -> silu(g)*u ->
    w_down, in THREE pallas_calls instead of four.

    The saving comes from ``w8a8_swiglu_matmul_p``: the grouped gate/up
    matmul's epilogue stages the full clamped output row in VMEM, computes
    the SwiGLU pairing in-register, and emits the w_down projection's PDQ
    prologue (hsw_q, s_x, s1, s2) alongside - so no standalone
    ``pdq_prologue_p`` launch runs between the two matmuls (DESIGN.md
    "Decode fast path").

    Falls back to the exact unfused composition (``pdq_dense_grouped`` +
    jnp silu + ``pdq_dense``) whenever the fused epilogue cannot apply:
    ref/auto-off-TPU mode (bit-identical numerics preserved), tensor
    parallelism (each shard owns an N-slice of BOTH segments but the
    prologue needs the full hsw row), an active ``pdq_guard`` (the
    fallback branch needs the guarded gate/up output), or a group layout
    that is not two equal lane-padded segments.
    """
    if out_dtype is None:
        out_dtype = jnp.float32
    segs = grec["segs"]
    bm, bn, bk = block
    fused = (_use_kernel() and not _PDQ_GUARD and _TP is None
             and len(segs.sizes) == 2 and segs.padded[0] == segs.padded[1]
             and segs.padded[0] % bn == 0)
    if not fused:
        g, u = pdq_dense_grouped(x, grec, out="fp", out_dtype=out_dtype,
                                 block=block, prologue_block=prologue_block)
        h = jax.nn.silu(g) * u
        return pdq_dense(h, down_rec, out="fp", out_dtype=out_dtype,
                         block=block, prologue_block=prologue_block)

    lead = x.shape[:-1]
    K = x.shape[-1]
    M = 1
    for d in lead:
        M *= d
    Nt = segs.total
    reps = np.array([p // bn for p in segs.padded])
    nb = int(reps.sum())

    x_q, s_x, s1, s2 = pdq_prologue(x, block=prologue_block)
    lo, hi, s_out, z_out = pdq_interval(grec, s1, s2)           # (..., 2)
    lo_g = (-128.0 - z_out) * s_out
    hi_g = (127.0 - z_out) * s_out

    def blockwise(a):
        return jnp.repeat(a, reps, axis=-1, total_repeat_length=nb)

    # the staging scratch holds a full (bm, Nt) f32 row block: shrink bm
    # for wide MLPs so it stays well under VMEM
    while bm > 8 and bm * Nt * 4 > 8 * 1024 * 1024:
        bm //= 2
    pads = dict(axis=0, mult=bm)
    lo_b = blockwise(lo_g).reshape(M, nb)
    hi_b = blockwise(hi_g).reshape(M, nb)
    y, _hsw, hsw_q, sxo, s1o, s2o = w8a8_swiglu_matmul_p(
        _pad_to(_pad_to(x_q.reshape(M, K), 0, bm), 1, bk),
        _pad_to(grec["q"], 0, bk),
        _pad_to(_norm_row(s_x, M, jnp.float32), **pads, value=1.0),
        _pad_to(_norm_row(0, M, jnp.int32), **pads),
        grec["scale"].reshape(1, Nt), grec["colsum"].reshape(1, Nt),
        _pad_to(lo_b, **pads), _pad_to(hi_b, **pads),
        block=(bm, bn, bk), interpret=_interpret(), out_dtype=jnp.float32)
    _tel_clip(y[:M], jnp.repeat(lo_b, bn, axis=-1),
              jnp.repeat(hi_b, bn, axis=-1))

    dff, N2 = down_rec["q"].shape
    hq = hsw_q[:M, :dff].reshape(*lead, dff)
    sxo = sxo[:M].reshape(*lead, 1)
    lo2, hi2, so2, zo2 = pdq_interval(down_rec, s1o[:M].reshape(*lead, 1),
                                      s2o[:M].reshape(*lead, 1))
    lo_g2 = (-128.0 - zo2) * so2
    hi_g2 = (127.0 - zo2) * so2
    y2 = w8a8_matmul(hq, down_rec["q"], sxo, 0, down_rec["scale"],
                     colsum=down_rec["colsum"], fp_range=(lo_g2, hi_g2),
                     out_dtype=out_dtype, block=block)
    _tel_clip(y2, lo_g2, hi_g2)
    return y2


def pdq_dense_unfused(x, wrec):
    """The pre-fusion serving path, kept as the oracle/baseline: 3 reads of
    x (amax / quantize / act_stats) + requant matmul + jnp dequant.

    ``pdq_dense(out='fp')`` must match this to within one int8 step of the
    predicted grid (tests/test_kernels.py); benchmarks/bench_pdq_dense.py
    times the two against each other.  Returns (y fp32, s_out per-row).
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8)
    s_x = amax / 127.0
    x_q = jnp.clip(jnp.round(x32 / s_x[..., None]), -127, 127).astype(jnp.int8)
    s1, s2 = act_stats(x32)
    lo, hi, s_out, z_out = pdq_interval(wrec, s1[..., None], s2[..., None])
    z_out = z_out.astype(jnp.int32)
    y_q = w8a8_matmul(x_q, wrec["q"], s_x[..., None], 0, wrec["scale"],
                      s_out, z_out, colsum=wrec["colsum"])
    y = (y_q.astype(jnp.float32) - z_out.astype(jnp.float32)) * s_out
    return y, s_out


def act_stats(x, gamma: int = 1, *, block=(256, 512)):
    """Fused (sum x, sum x^2) over the last axis; gamma subsamples the
    second-to-last ("position") axis.  Returns arrays shaped like x[..., 0]."""
    if x.ndim > 2 and gamma > 1:
        x = x[..., ::gamma, :]
    lead = x.shape[:-1]
    K = x.shape[-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    if not _use_kernel():
        s1, s2 = ref.act_stats_ref(x2)
        return s1.reshape(lead), s2.reshape(lead)
    bm, bk = block
    xp = _pad_to(_pad_to(x2, 0, bm), 1, bk)
    s1, s2 = act_stats_p(xp, block=(bm, bk), interpret=_interpret())
    return s1[:M].reshape(lead), s2[:M].reshape(lead)


def quantize(x, scale, zero_point, *, per_channel: bool = False):
    """Affine int8 quantize. scale/zp: per-row (broadcast over last axis) by
    default, or per-channel (last axis) with per_channel=True."""
    lead = x.shape[:-1]
    N = x.shape[-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, N)
    if per_channel:
        s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, N))
        z = jnp.broadcast_to(jnp.asarray(zero_point, jnp.int32).reshape(1, -1), (1, N))
    else:
        s = _norm_row(scale, M, jnp.float32)
        z = _norm_row(zero_point, M, jnp.int32)
    if not _use_kernel():
        return ref.quantize_ref(x2, s, z).reshape(*lead, N)
    xp = _pad_to(_pad_to(x2, 0, 256), 1, 256)
    sp = _pad_to(s, 1, 256, value=1.0) if per_channel else _pad_to(s, 0, 256, value=1.0)
    zp = _pad_to(z, 1, 256) if per_channel else _pad_to(z, 0, 256)
    q = quantize_p(xp, sp, zp, interpret=_interpret())
    return q[:M, :N].reshape(*lead, N)


def dequantize(q, scale, zero_point, *, per_channel: bool = False, out_dtype=jnp.float32):
    lead = q.shape[:-1]
    N = q.shape[-1]
    M = 1
    for d in lead:
        M *= d
    q2 = q.reshape(M, N)
    if per_channel:
        s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, N))
        z = jnp.broadcast_to(jnp.asarray(zero_point, jnp.int32).reshape(1, -1), (1, N))
    else:
        s = _norm_row(scale, M, jnp.float32)
        z = _norm_row(zero_point, M, jnp.int32)
    if not _use_kernel():
        return ref.dequantize_ref(q2, s, z, out_dtype).reshape(*lead, N)
    qp_ = _pad_to(_pad_to(q2, 0, 256), 1, 256)
    sp = _pad_to(s, 1, 256, value=1.0) if per_channel else _pad_to(s, 0, 256, value=1.0)
    zp_ = _pad_to(z, 1, 256) if per_channel else _pad_to(z, 0, 256)
    y = dequantize_p(qp_, sp, zp_, out_dtype=out_dtype, interpret=_interpret())
    return y[:M, :N].reshape(*lead, N).astype(out_dtype)


def decode_attend_i8kv(q, k_q, v_q, k_scale, v_scale, length, *, bs: int = 256,
                       wo_prologue: bool = False, pro_dtype=None):
    """Batched flash-decode over an int8 KV cache in KERNEL layout.

    q: (B, H, Dh) f32; k_q/v_q: (B, Hkv, S, Dh) int8;
    k_scale/v_scale: (B, Hkv, S) f32; length: (B,) int32.
    Returns (B, H, Dh) f32.

    ``wo_prologue=True`` additionally runs the wo projection's PDQ prologue
    over the flattened (H * Dh,) output row inside the attend kernel's
    output stage and returns (o (B, H, Dh) f32, o_q (B, H*Dh) int8,
    s_x, s1, s2 each (B, 1) f32) - feed them to
    ``pdq_dense_from_prologue`` and the quantized wo projection costs one
    launch instead of two.  ``pro_dtype`` (default f32) is the compute
    dtype the unfused path would have cast o to before its prologue; the
    ref path reproduces that cast so numerics stay bit-identical to the
    unfused composition.

    The cache is head-major so the per-step decode path does no layout
    work: ``models.attention.init_cache`` allocates it this way (S rounded
    up to a 128 multiple) and ``_cache_write`` scatters new tokens straight
    into kernel layout.  With S % block == 0 the ``_pad_to`` calls below
    are trace-time no-ops; only ragged direct callers pay a one-off batched
    pad (outside the vmapped per-token path, not per decode step).
    """
    B, H, Dh = q.shape
    Hkv, S = k_q.shape[1], k_q.shape[2]
    G = H // Hkv

    if not _use_kernel():
        # jnp oracle keeps the logical (S, Hkv, ...) layout
        k_l = jnp.transpose(k_q, (0, 2, 1, 3))
        v_l = jnp.transpose(v_q, (0, 2, 1, 3))
        ks_l = jnp.transpose(k_scale, (0, 2, 1))
        vs_l = jnp.transpose(v_scale, (0, 2, 1))
        o = jax.vmap(ref.decode_attend_i8kv_ref)(q, k_l, v_l, ks_l, vs_l, length)
        if not wo_prologue:
            return o
        of = o.astype(pro_dtype) if pro_dtype is not None else o
        o_q, s_x, s1, s2 = ref.pdq_prologue_ref(of.reshape(B, H * Dh))
        return o, o_q, s_x, s1, s2

    # prefer a scan block that divides S (true whenever the cache came from
    # init_cache, which rounds S to a 128 multiple) over padding per call
    bss = min(bs, S)
    while bss > 32 and S % bss:
        bss //= 2
    k_q = _pad_to(k_q, 2, bss)
    v_q = _pad_to(v_q, 2, bss)
    k_scale = _pad_to(k_scale, 2, bss, value=1.0)
    v_scale = _pad_to(v_scale, 2, bss, value=1.0)

    if wo_prologue:
        def one_fused(q1, k1, v1, ks1, vs1, len1):
            o, oq, sx, s1, s2 = decode_attend_i8kv_fused_p(
                q1.reshape(Hkv, G, Dh), k1, v1, ks1, vs1,
                len1.reshape(1, 1).astype(jnp.int32),
                bs=bss, interpret=_interpret())
            return (o.reshape(H, Dh), oq.reshape(H * Dh),
                    sx.reshape(1), s1.reshape(1), s2.reshape(1))

        return jax.vmap(one_fused)(q, k_q, v_q, k_scale, v_scale, length)

    def one(q1, k1, v1, ks1, vs1, len1):
        o = decode_attend_i8kv_p(q1.reshape(Hkv, G, Dh), k1, v1, ks1, vs1,
                                 len1.reshape(1, 1).astype(jnp.int32),
                                 bs=bss, interpret=_interpret())
        return o.reshape(H, Dh)

    return jax.vmap(one)(q, k_q, v_q, k_scale, v_scale, length)


def cache_scatter_rows(dst, src, src_map, *, batch_axis: int = 0, _entry=None):
    """Batched cache-row scatter: out row s = src[src_map[s]] when
    src_map[s] >= 0, else dst[s] kept bit-exactly.  Any dtype (the int8
    kernel-layout KV leaves included) and any trailing shape.

    ``batch_axis=1`` handles stacked per-block cache leaves (n, B, ...):
    the stack is folded into the row axis and src_map is expanded per
    stack entry, so the kernel still sees a flat (rows, R) copy problem
    with no transposes.

    ``_entry`` picks the Pallas launch on the kernel path (slot-row
    ``cache_scatter_p`` by default; ``cache_scatter_pages`` routes the
    paged entry through here - same machinery, page-sized rows).
    """
    src_map = jnp.asarray(src_map, jnp.int32)
    if batch_axis == 1:
        n, B = dst.shape[0], dst.shape[1]
        Bs = src.shape[1]
        m = jnp.where(src_map[None, :] >= 0,
                      src_map[None, :] + Bs * jnp.arange(n)[:, None],
                      -1).reshape(n * B)
        out = cache_scatter_rows(dst.reshape((n * B,) + dst.shape[2:]),
                                 src.reshape((n * Bs,) + src.shape[2:]), m,
                                 _entry=_entry)
        return out.reshape(dst.shape)
    assert batch_axis == 0, batch_axis
    B = dst.shape[0]
    R = 1
    for d in dst.shape[1:]:
        R *= d
    if not _use_kernel():
        take = jnp.take(src, jnp.clip(src_map, 0, src.shape[0] - 1), axis=0)
        keep = (src_map >= 0).reshape((B,) + (1,) * (dst.ndim - 1))
        return jnp.where(keep, take, dst)
    d2 = _pad_to(dst.reshape(B, R), 1, 128)
    s2 = _pad_to(src.reshape(src.shape[0], R), 1, 128)
    entry = cache_scatter_p if _entry is None else _entry
    out = entry(src_map, d2, s2, interpret=_interpret())
    return out[:, :R].reshape(dst.shape)


# ---------------------------------------------------------------------------
# Paged KV-cache pool: page-rows views + paged scatter (serve/pages.py's
# device half).  A cache leaf's seq axis is split into fixed-size pages and
# the page index is folded into the batch/row axis, after which every pool
# movement (prefill landing, decode gather, COW copy, spill restore) is the
# SAME row-scatter problem cache_scatter_rows already solves.
# ---------------------------------------------------------------------------


def to_page_rows(x, seq_axis: int, page: int, *, batch_axis: int = 0):
    """Reshape a logical cache leaf to PAGE-ROWS: the seq axis (length S,
    S % page == 0) splits into (S//page, page) and the page index merges
    into the batch axis, giving (..., B * S//page, *page_block) with the
    page block laid out exactly like a physical pool page.  ``batch_axis``
    is 0 for head/tail leaves (B leading) and 1 for stacked block leaves
    (n_blocks, B, ...)."""
    S = x.shape[seq_axis]
    assert S % page == 0, (S, page)
    n_pp = S // page
    split = x.shape[:seq_axis] + (n_pp, page) + x.shape[seq_axis + 1:]
    x = jnp.reshape(x, split)
    lead = batch_axis + 1
    x = jnp.moveaxis(x, seq_axis, lead)          # page index next to batch
    B = x.shape[batch_axis]
    return jnp.reshape(
        x, x.shape[:batch_axis] + (B * n_pp,) + x.shape[lead + 1:])


def from_page_rows(x, shape, seq_axis: int, page: int, *, batch_axis: int = 0):
    """Inverse of ``to_page_rows``: page-rows back to the logical leaf
    layout ``shape``."""
    S = shape[seq_axis]
    n_pp = S // page
    B = shape[batch_axis]
    lead = batch_axis + 1
    x = jnp.reshape(x, x.shape[:batch_axis] + (B, n_pp) + x.shape[lead:])
    x = jnp.moveaxis(x, lead, seq_axis)
    return jnp.reshape(x, shape)


def cache_scatter_pages(dst, src, page_map, *, batch_axis: int = 0):
    """Row scatter over PAGES: ``dst``/``src`` are page-rows arrays (a
    physical pool, or a logical leaf through ``to_page_rows``) and
    ``page_map[p] = q`` moves src page-row q into dst page-row p (-1
    keeps dst bit-exactly).  Kernel path launches
    ``kv_cache.cache_scatter_pages_p`` - the paged front door of the same
    scalar-prefetched scatter machinery."""
    return cache_scatter_rows(dst, src, page_map, batch_axis=batch_axis,
                              _entry=cache_scatter_pages_p)
