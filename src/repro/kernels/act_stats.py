"""Pallas TPU kernel: fused input-moment reduction (paper Eqs. 8-9).

One pass over the input produces per-row s1 = sum_k x and s2 = sum_k x^2 -
the entire cost of the PDQ surrogate for a linear layer.  Fusing both sums
means the input is read from HBM exactly once; the outputs are O(M) scalars
(the paper's "2 b' bits of memory overhead", here 2 VREGs per row-block).

Sampling-stride gamma is applied by the wrapper (row subsampling) so the
kernel itself stays dense and aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s1_ref, s2_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)

    xb = x_ref[...].astype(jnp.float32)
    s1_ref[...] += jnp.sum(xb, axis=-1, keepdims=True)
    s2_ref[...] += jnp.sum(xb * xb, axis=-1, keepdims=True)


def act_stats_p(
    x: jax.Array,                      # (M, K)
    *,
    block: tuple[int, int] = (256, 512),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas call; M, K must be multiples of the block."""
    M, K = x.shape
    bm, bk = block
    assert M % bm == 0 and K % bk == 0, (
        f"act_stats_p requires block-multiple shapes: got x ({M}, {K}) with "
        f"block ({bm}, {bk}) - trailing rows/cols would be silently dropped "
        f"from the sums; pad the inputs or call repro.kernels.ops.act_stats, "
        f"which pads for you")
    n_k = K // bk
    grid = (M // bm, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, k: (i, k))],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return out[0][:, 0], out[1][:, 0]
