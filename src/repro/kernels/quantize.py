"""Pallas TPU kernel: elementwise affine quantize / dequantize.

Used to write int8 tensors (e.g. the KV cache) directly from bf16/f32
activations with a PDQ-predicted (per-row) or per-channel scale, without a
second range-finding pass.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, s_ref, z_ref, o_ref):
    q = jnp.round(x_ref[...].astype(jnp.float32) / s_ref[...]) + z_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, z_ref, o_ref):
    o_ref[...] = ((q_ref[...].astype(jnp.int32) - z_ref[...]).astype(jnp.float32)
                  * s_ref[...]).astype(o_ref.dtype)


def _scale_spec(scale_shape, bm, bn):
    if scale_shape[0] == 1:        # per-channel (1, N)
        return pl.BlockSpec((1, bn), lambda i, j: (0, j))
    return pl.BlockSpec((bm, 1), lambda i, j: (i, 0))   # per-row (M, 1)


def quantize_p(x, scale, zero_point, *, block=(256, 256), interpret=False):
    """x (M, N) float -> int8; scale/zero_point are (M,1) or (1,N)."""
    M, N = x.shape
    bm, bn = min(block[0], M), min(block[1], N)
    assert M % bm == 0 and N % bn == 0, (
        f"quantize_p requires block-multiple shapes: got x ({M}, {N}) with "
        f"block ({bm}, {bn}) - trailing rows/cols would be silently dropped; "
        f"pad the inputs or call repro.kernels.ops.quantize, which pads")
    grid = (M // bm, N // bn)
    sspec = _scale_spec(scale.shape, bm, bn)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)), sspec, sspec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        interpret=interpret,
    )(x, scale, zero_point)


def dequantize_p(q, scale, zero_point, *, out_dtype=jnp.float32, block=(256, 256),
                 interpret=False):
    M, N = q.shape
    bm, bn = min(block[0], M), min(block[1], N)
    assert M % bm == 0 and N % bn == 0, (
        f"dequantize_p requires block-multiple shapes: got q ({M}, {N}) with "
        f"block ({bm}, {bn}) - trailing rows/cols would be silently dropped; "
        f"pad the inputs or call repro.kernels.ops.dequantize, which pads")
    grid = (M // bm, N // bn)
    sspec = _scale_spec(scale.shape, bm, bn)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)), sspec, sspec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(q, scale, zero_point)
